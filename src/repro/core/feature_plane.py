"""FeaturePlane — the pluggable feature-fetch seam of the batch-generation
hot path (paper §III-A/B; the "gather" stage of sample → gather → transfer).
Training (core/pipeline.py) and online inference serving
(serve/gnn_engine.py) fetch through the SAME plane object, so the γ/Θ
cache and its hit/miss accounting carry across the train → serve boundary.

Every consumer of node features goes through ONE interface:

  * ``HostFeaturePlane``   — today's numpy path: ``FeatureCache.fetch``
    when a cache is configured, a direct host-store gather otherwise.
    Bit-exact with the pre-plane code (the regression anchor).
  * ``DeviceFeaturePlane`` — the cache table and the slot map (device map)
    live as jax device arrays; a batch fetch looks slots up on device and
    gathers resident rows with the Pallas kernel
    (``kernels/gather.cache_gather``), falling back to the host feature
    store for misses.  Accounting, FIFO insertion and resize semantics are
    delegated to the SAME ``FeatureCache`` bookkeeping, so the two planes
    are bit-exact and stats-exact on the same request stream.

``make_feature_plane`` picks the backend from
``GNNConfig.sampling_device`` (``cpu | device | auto`` — auto probes
``jax.devices()`` and chooses the device plane only when a non-CPU
accelerator is attached; the device plane still RUNS on CPU hosts through
the kernel's interpret mode, which is what the parity tests exercise).

Reconfiguration contract (the autotune controller's live swaps):

  * ``resize``/γ-swap — the underlying ``FeatureCache`` mutates in place;
    the device plane detects the mutation through ``FeatureCache.version``
    and re-uploads, DELETING the stale device buffers first (the donation
    step — a live Θ sweep must not accumulate dead cache tables in HBM).
  * plane swap — ``Pipeline.reconfigure(sampling_device=...)`` drains the
    executor and rebuilds the plane around the same cache object, so
    hit/miss accounting survives a cpu↔device migration.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.core.cache import FeatureCache
from repro.graph.storage import Graph
from repro.kernels.pad_plan import bucket_plan

# device-plane gather is issued in bounded row chunks: each distinct padded
# shape costs one jit trace (expensive in interpret mode), so chunking plus
# pow2 bucketing of the tail keeps the set of compiled shapes small and
# independent of the batch-size schedule.  4096 covers the paper's batch
# regime in ONE dispatch — per-chunk dispatch overhead, not gather
# bandwidth, dominates the device plane's fixed cost
GATHER_CHUNK_ROWS = 4096


def _bucket(n: int) -> int:
    """Round ``n`` up to a pow2 (≥ 8) so jit retraces stay bounded —
    memoized through the shared pad-plan cache (kernels/pad_plan.py)."""
    return bucket_plan(n)


def _scatter_update(buf, idx, vals):
    """Dirty-row scatter into a device mirror buffer.  ``idx`` is padded
    to a pow2 length with out-of-range indices, which ``mode="drop"``
    discards; the input buffer is donated, so the update is in-place-like
    and never holds two live copies of the cache table in HBM."""
    return buf.at[idx].set(vals, mode="drop")


_scatter_update_jit = None


def _scatter(buf, idx, vals):
    global _scatter_update_jit
    if _scatter_update_jit is None:
        import functools
        import jax
        _scatter_update_jit = functools.partial(jax.jit, donate_argnums=(0,))(
            _scatter_update)
    return _scatter_update_jit(buf, idx, vals)


def _fused_pack_impl(aux, idx, vals, enc):
    """Single-dispatch step-input packing: scatter the miss rows into the
    donated sideband and move the encoding to device ALONGSIDE, in one
    jitted call.  Per-dispatch overhead (~100 µs on this container) is
    what made the old 3-conversions-plus-scatter sequence dominate
    small-batch cost — one dispatch instead of four is most of the
    small-batch win.  ``idx`` pads to a pow2 bucket with out-of-range
    entries (``mode="drop"``); padded ``vals`` rows are dropped with
    them, so their (uninitialized) contents never land in the buffer."""
    return enc, aux.at[idx].set(vals, mode="drop")


_fused_pack_jit = None


def _fused_pack(aux, idx, vals, enc):
    global _fused_pack_jit
    if _fused_pack_jit is None:
        import functools
        import jax
        _fused_pack_jit = functools.partial(jax.jit, donate_argnums=(0,))(
            _fused_pack_impl)
    return _fused_pack_jit(aux, idx, vals, enc)


def _host_pack_impl(enc, aux):
    """Host twin of ``_fused_pack``: one dispatch moves the all-sideband
    encoding + rows to the step, instead of one conversion each."""
    return enc, aux


_host_pack_jit = None


def _host_pack(enc, aux):
    global _host_pack_jit
    if _host_pack_jit is None:
        import jax
        _host_pack_jit = jax.jit(_host_pack_impl)
    return _host_pack_jit(enc, aux)


def _run_fused(enc, neigh_idx, table, aux, use_pallas: bool, interpret: bool,
               mode: str = "mean"):
    """Bucket the fused gather+aggregate inputs to pow2 row counts (jit
    retraces stay bounded across the batch-size schedule) and slice the
    padding back off.  ``enc`` pads with -1 (→ ``aux[0]``, never referenced
    by a real dst row); neighbor rows pad with -1 (masked)."""
    import jax.numpy as jnp
    from repro.kernels.fused_gather_agg.ops import gather_aggregate
    ns = len(enc)
    nd, fan = neigh_idx.shape
    nsp, ndp = _bucket(ns), _bucket(nd)
    enc_p = np.full(nsp, -1, np.int32)
    enc_p[:ns] = enc
    idx_p = np.full((ndp, fan), -1, np.int32)
    idx_p[:nd] = neigh_idx
    nap = _bucket(max(len(aux), 1))
    aux_p = np.zeros((nap, aux.shape[1]), np.float32)
    aux_p[:len(aux)] = aux
    h, a = gather_aggregate(jnp.asarray(enc_p), jnp.asarray(idx_p),
                            jnp.asarray(table), jnp.asarray(aux_p),
                            mode=mode, use_pallas=use_pallas,
                            interpret=interpret)
    return np.asarray(h)[:nd], np.asarray(a)[:nd]


class FeaturePlane:
    """Backend-pluggable feature-fetch interface (host implementation).

    ``fetch`` is the hot-path read (through the cache, with accounting);
    ``fill_rows`` is the write side used by the halo exchange — it updates
    the host store AND any cache-resident copy of the written rows, so a
    fill is visible no matter which backend serves the next fetch.
    """

    backend = "cpu"

    def __init__(self, graph: Graph, cache: Optional[FeatureCache] = None):
        self.graph = graph
        self.cache = cache
        self.store = None               # attached FeatureStore (subscribe_to)
        # per-batch gather counters — the read-side twin of the device
        # plane's sync_* upload counters.  Every plane read (``fetch``,
        # the fused ``gather_aggregate`` read, the step-time
        # ``fused_inputs``) ticks them: ``gather_dispatches`` counts
        # gather invocations (device plane: one per kernel dispatch, so
        # "one dispatch per batch" is an assertable claim; host plane:
        # one per plane call, the numpy gather has no finer dispatch
        # granularity); ``gather_rows`` counts the rows those dispatches
        # resolved.
        self.gather_dispatches = 0
        self.gather_rows = 0
        self._fused_table = None        # host fused_inputs' 1-row dummy

    # -- reads ---------------------------------------------------------------
    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Gather features for ``ids`` (n,) → (n, F) float32."""
        self.gather_dispatches += 1
        self.gather_rows += len(ids)
        if self.cache is not None:
            return self.cache.fetch(ids)
        return self.graph.features[np.asarray(ids, dtype=np.int64)]

    def gather_aggregate(self, ids: np.ndarray, neigh_idx: np.ndarray,
                         mode: str = "mean"):
        """Fused layer-0 read (``GNNConfig.fused_gather_agg``): resolve the
        input-hop rows and the masked neighbor aggregate (``mode``: mean
        or sum) in one kernel call, returning ``(h_dst (n_dst, F), agg
        (n_dst, F))`` where ``n_dst = neigh_idx.shape[0]`` (dst ids are
        the prefix of ``ids``).

        Host backend: fetch through the cache (same accounting as
        ``fetch`` — stats-exactness is a tested invariant) and run the
        SAME jitted fused op with an all-sideband encoding, so both
        backends compute the aggregate from bitwise-identical resolved
        rows — the cpu/device bit-exactness anchor."""
        ids = np.asarray(ids, dtype=np.int64)
        rows = self.fetch(ids)           # counts the gather_* traffic
        enc = -np.arange(1, len(ids) + 1, dtype=np.int32)
        table = np.zeros((1, self.graph.feat_dim), np.float32)
        return _run_fused(enc, neigh_idx, table, rows,
                          use_pallas=False, interpret=False, mode=mode)

    def fused_inputs(self, ids: np.ndarray, cap: int):
        """Encoded layer-0 inputs for the all-hop fused train step
        (models/gnn.py ``make_train_step_allfused``): ``(enc (cap,) int32,
        aux (cap, F) float32, table)`` padded to the FIXED input-level cap
        (graph/batch.py ``compute_level_caps``) so every batch hits one
        jit signature.  Padded enc entries are -1 → ``aux[0]``, never
        referenced by a real dst row.

        Host backend: all-sideband encoding — rows are fetched through the
        cache (same accounting as ``fetch``), ``enc[i] = -(i+1)`` and the
        table is a 1-row dummy, so the step resolves bitwise-identical
        rows to the device plane's slot encoding."""
        import jax.numpy as jnp
        ids = np.asarray(ids, dtype=np.int64)
        n = len(ids)
        if n > cap:
            raise ValueError(f"{n} input ids exceed level cap {cap}")
        rows = self.fetch(ids)           # counts the gather_* traffic
        enc = np.full(cap, -1, np.int32)
        enc[:n] = -np.arange(1, n + 1, dtype=np.int32)
        aux = np.zeros((cap, self.graph.feat_dim), np.float32)
        aux[:n] = rows
        if self._fused_table is None:
            self._fused_table = jnp.zeros((1, self.graph.feat_dim),
                                          jnp.float32)
        enc_dev, aux_dev = _host_pack(enc, aux)
        return enc_dev, aux_dev, self._fused_table

    # -- writes (halo fills / streaming updates) -----------------------------
    def subscribe_to(self, store) -> "FeaturePlane":
        """Wire this plane into a ``graph/storage.py`` ``FeatureStore``:
        every streamed ``update_rows`` patches cache-resident copies and
        invalidates device mirrors (the store itself already wrote the
        host rows), so the serving engine (serve/gnn_engine.py) and a
        live trainer observe the same drift through the same seam.  Any
        previous subscription is detached first (a plane tracks at most
        one store); the store is recorded so a plane swap
        (``Pipeline.reconfigure``) can migrate the subscription to the
        successor plane."""
        self.detach_store()
        self.store = store
        store.subscribe(self._on_store_update)
        return self

    def detach_store(self):
        """Unsubscribe from the attached store — a REPLACED plane must
        detach or streamed updates keep routing into the dead object
        while its successor's cache silently drifts
        (``Pipeline.reconfigure`` migrates the subscription)."""
        if self.store is not None:
            self.store.unsubscribe(self._on_store_update)
            self.store = None

    def _on_store_update(self, ids: np.ndarray, rows: np.ndarray):
        """Store subscriber: the store wrote the host rows already, so
        only resident copies need patching (version bump → mirror
        re-sync) — no redundant host-store rewrite per subscribed plane.
        A plane over a SUBGRAPH may be subscribed to a full-graph store
        (a fabric replica's plane, a test rig); ids outside this plane's
        node universe have no copy here and are dropped, not an error."""
        c = self.cache
        if c is not None:
            ids = np.asarray(ids, dtype=np.int64)
            rows = np.asarray(rows, dtype=np.float32)
            in_universe = ids < self.graph.num_nodes
            if not in_universe.all():
                ids, rows = ids[in_universe], rows[in_universe]
            if len(ids):
                c.patch_resident(ids, rows)

    def fill_rows(self, ids: np.ndarray, rows: np.ndarray):
        """Overwrite feature rows ``ids`` in the host store, propagating to
        cache-resident copies (and, on the device plane, invalidating the
        device mirror)."""
        ids = np.asarray(ids, dtype=np.int64)
        self.graph.features[ids] = rows
        c = self.cache
        if c is not None:
            # resident-copy patch + version bump (mirror invalidation)
            # live in ONE place: FeatureCache.patch_resident
            c.patch_resident(ids, np.asarray(rows, dtype=np.float32))

    # -- reconfiguration -----------------------------------------------------
    def resize(self, volume_mb: float, keep_residents: bool = True):
        """Episode-boundary Θ swap, routed through the plane so backend
        state (device mirrors) tracks the cache."""
        if self.cache is not None:
            self.cache.resize(volume_mb, keep_residents=keep_residents)

    @property
    def stats(self):
        return self.cache.stats if self.cache is not None else None


# back-compat alias: the host plane IS the base implementation
HostFeaturePlane = FeaturePlane


class DeviceFeaturePlane(FeaturePlane):
    """Device-resident gather: slot map + cache table as jax arrays, batch
    lookup through the Pallas ``cache_gather`` kernel, miss fallback to the
    host feature store.

    The ``FeatureCache`` object stays the single source of truth for the
    slot assignment, the replacement policy and the hit/miss accounting —
    this plane mirrors (storage, device_map) to the device and keeps the
    mirror coherent through the cache's dirty-row delta log
    (``FeatureCache.deltas_since``): a FIFO-inserting fetch or a streamed
    ``patch_resident`` scatters only the dirty rows into the live buffers
    (donated, so no second table materializes); a full delete + re-upload
    happens only on reallocation (``resize``/``_alloc``) or when the
    bounded log was dropped.  ``use_pallas=None`` resolves to the Pallas
    gather only when a real accelerator is attached — on CPU hosts the
    jitted pure-jnp reference path is both the fast AND the faithful
    choice (interpret-mode Pallas is a debugging vehicle, exercised by
    the kernel tests, not a production configuration).
    """

    backend = "device"

    def __init__(self, graph: Graph, cache: Optional[FeatureCache] = None,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 incremental_sync: bool = True):
        super().__init__(graph, cache)
        import jax
        accel = jax.devices()[0].platform in ("tpu", "gpu")
        self.use_pallas = use_pallas if use_pallas is not None else accel
        # interpret mode unless a real accelerator backs the default device
        self.interpret = interpret if interpret is not None else not accel
        self.incremental_sync = incremental_sync
        self._dev_table = None
        self._dev_slots = None
        self._version = -1
        self._epoch = -1
        # mirror-maintenance counters (the upload-counter test and
        # benchmarks/fig_gather.py read these): full uploads move
        # O(capacity) rows, scatters move O(dirty rows)
        self.sync_full_uploads = 0
        self.sync_row_scatters = 0
        self.sync_rows_scattered = 0
        self.sync_bytes_uploaded = 0    # host→device mirror traffic, exact
        # per-cap persistent aux sidebands for the all-hop fused path:
        # miss rows are scattered into a donated device buffer instead of
        # re-uploading a (cap, F) tensor per batch — the whole point of
        # the encoded-slot contract is that per-batch feature traffic is
        # O(misses), not O(cap)
        self._aux_bufs = {}
        # mode1 batch-gen workers share the plane: the mirror delete +
        # re-upload must never race a gather in another thread (a deleted
        # buffer mid-kernel is fatal, unlike the host path's benign numpy
        # interleavings), so sync + gather + insert run under one lock
        self._lock = threading.Lock()

    # -- device mirror -------------------------------------------------------
    def _ensure_synced(self):
        c = self.cache
        if self._dev_table is not None and self._version == c.version:
            return
        import jax
        import jax.numpy as jnp
        deltas = (c.deltas_since(self._version, self._epoch)
                  if self.incremental_sync and self._dev_table is not None
                  else None)
        if deltas is None:
            # reallocation (or the bounded delta log was dropped): the
            # buffer shapes may have changed — delete the stale mirror
            # and re-upload the whole table
            for buf in (self._dev_table, self._dev_slots):
                if buf is not None:
                    buf.delete()
            self._dev_table = jax.device_put(c.storage)
            self._dev_slots = jax.device_put(c.device_map)
            self.sync_full_uploads += 1
            self.sync_bytes_uploaded += (c.storage.nbytes
                                         + c.device_map.nbytes)
        else:
            dirty_slots, dirty_ids = deltas
            if len(dirty_slots):
                # pad to a pow2 with out-of-range indices (dropped by the
                # scatter) so jit retraces stay bounded
                p = _bucket(len(dirty_slots))
                idx = np.full(p, c.capacity, np.int32)
                idx[:len(dirty_slots)] = dirty_slots
                vals = np.zeros((p, self.graph.feat_dim), np.float32)
                vals[:len(dirty_slots)] = c.storage[dirty_slots]
                self._dev_table = _scatter(self._dev_table,
                                           jnp.asarray(idx),
                                           jnp.asarray(vals))
                self.sync_bytes_uploaded += vals.nbytes + idx.nbytes
            if len(dirty_ids):
                p = _bucket(len(dirty_ids))
                idx = np.full(p, self.graph.num_nodes, np.int64)
                idx[:len(dirty_ids)] = dirty_ids
                vals = np.zeros(p, np.int32)
                vals[:len(dirty_ids)] = c.device_map[dirty_ids]
                self._dev_slots = _scatter(self._dev_slots,
                                           jnp.asarray(idx),
                                           jnp.asarray(vals))
                self.sync_bytes_uploaded += vals.nbytes + idx.nbytes
            self.sync_row_scatters += 1
            self.sync_rows_scattered += len(dirty_slots) + len(dirty_ids)
        self._version = c.version
        self._epoch = c.epoch

    def device_bytes(self) -> int:
        """HBM footprint of the mirror — what is ACTUALLY resident on
        device: 0 before the first upload and after the buffers were
        deleted (the host-side ``c.storage`` numpy array is not HBM)."""
        total = 0
        for buf in (self._dev_table, self._dev_slots):
            if buf is not None and not buf.is_deleted():
                total += buf.nbytes
        return total

    # -- reads ---------------------------------------------------------------
    def fetch(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        c = self.cache
        if c is None or not c.capacity:
            # nothing resident on device — same contract as the host plane
            return super().fetch(ids)
        with self._lock:
            return self._fetch_locked(ids, c)

    def _fetch_locked(self, ids: np.ndarray, c: FeatureCache) -> np.ndarray:
        import jax.numpy as jnp
        from repro.kernels.gather.ops import cache_gather
        self._ensure_synced()
        n = len(ids)
        out = np.empty((n, self.graph.feat_dim), np.float32)
        # the host-side device_map is bit-identical to the synced _dev_slots
        # mirror (both under this lock), so the miss set is known BEFORE the
        # device gather completes — that is what lets the host-store gather
        # for misses overlap the device gather of resident rows.  The slot
        # translation rides the SAME read (one map lookup, two uses): the
        # kernel receives the slots directly instead of re-deriving them
        # from the _dev_slots mirror with a device-side take per chunk
        slots_np = c.device_map[ids]
        miss = slots_np < 0
        pending = []                     # (offset, rows_on_device) per chunk
        for a in range(0, n, GATHER_CHUNK_ROWS):
            m = len(slots_np[a:a + GATHER_CHUNK_ROWS])
            mp = min(_bucket(m), GATHER_CHUNK_ROWS)
            # pad slots resolve to -1 (a miss) — zero rows, sliced off below
            pad = np.full(mp, -1, dtype=np.int32)
            pad[:m] = slots_np[a:a + m]
            rows, _ = cache_gather(jnp.asarray(pad), self._dev_table,
                                   use_pallas=self.use_pallas,
                                   interpret=self.interpret)
            # jax dispatch is async: don't block on the result yet
            pending.append((a, m, rows))
        # double-buffered miss path: gather missed rows from the host
        # store while the device works through the resident-row gathers
        miss_ids = ids[miss]
        host_rows = self.graph.features[miss_ids] if len(miss_ids) else None
        self.gather_dispatches += len(pending)
        self.gather_rows += n
        for a, m, rows in pending:
            out[a:a + m] = np.asarray(rows)[:m]      # blocks per chunk
        if len(miss_ids):
            out[miss] = host_rows
        # one accounting implementation for both planes (stats-exactness
        # is a tested invariant); a FIFO insert bumps version → re-sync
        c.account_fetch(~miss, miss_ids)
        return out

    def gather_aggregate(self, ids: np.ndarray, neigh_idx: np.ndarray,
                         mode: str = "mean"):
        """Fused layer-0 read against the device mirror: resident rows are
        addressed by cache slot (no batch feature tensor materializes on
        the kernel path), misses ride the host-gathered ``aux`` sideband.
        Bit-exact with the host plane: both resolve the same row values,
        then run the same aggregation."""
        ids = np.asarray(ids, dtype=np.int64)
        c = self.cache
        if c is None or not c.capacity:
            return super().gather_aggregate(ids, neigh_idx, mode=mode)
        with self._lock:
            self._ensure_synced()
            slots = c.device_map[ids]
            hit = slots >= 0
            miss_ids = ids[~hit]
            enc = np.empty(len(ids), np.int32)
            enc[hit] = slots[hit]
            enc[~hit] = -np.arange(1, len(miss_ids) + 1, dtype=np.int32)
            aux = (self.graph.features[miss_ids] if len(miss_ids)
                   else np.zeros((0, self.graph.feat_dim), np.float32))
            self.gather_dispatches += 1
            self.gather_rows += len(ids)
            out = _run_fused(enc, neigh_idx, self._dev_table, aux,
                             use_pallas=self.use_pallas,
                             interpret=self.interpret, mode=mode)
            # same accounting seam as _fetch_locked (stats-exact invariant)
            c.account_fetch(hit, miss_ids)
        return out

    def fused_inputs(self, ids: np.ndarray, cap: int):
        """Device twin of the host ``fused_inputs``: resident rows are
        encoded as cache-table slots (``enc >= 0`` — ZERO feature bytes
        move for them), misses are scattered into a persistent per-cap
        device sideband through the donated ``_scatter`` path, so
        per-batch feature traffic is O(miss rows), never O(cap).  The
        returned ``table`` is the live device mirror — (capacity+pad, F)
        is a fixed shape, so every batch hits the one jitted step
        signature.

        The consuming train step must be serialized (the trainers block
        on ``float(loss)`` per step) — the sideband buffer is donated on
        the NEXT batch's scatter, which must not race an in-flight step."""
        ids = np.asarray(ids, dtype=np.int64)
        c = self.cache
        if c is None or not c.capacity:
            return super().fused_inputs(ids, cap)
        import jax.numpy as jnp
        n = len(ids)
        if n > cap:
            raise ValueError(f"{n} input ids exceed level cap {cap}")
        with self._lock:
            self._ensure_synced()
            slots = c.device_map[ids]
            hit = slots >= 0
            miss_ids = ids[~hit]
            enc = np.full(cap, -1, np.int32)
            enc[:n][hit] = slots[hit]
            enc[:n][~hit] = -np.arange(1, len(miss_ids) + 1, dtype=np.int32)
            aux = self._aux_bufs.get(cap)
            if aux is None:
                aux = jnp.zeros((cap, self.graph.feat_dim), jnp.float32)
            # pow2-padded miss scatter with out-of-range pad indices
            # (dropped — padded vals rows never land in the buffer), same
            # discipline as the mirror sync; m == 0 rides the minimal
            # bucket so EVERY batch is exactly one packing dispatch
            m = len(miss_ids)
            p = min(_bucket(max(m, 1)), cap)
            idx = np.full(p, cap, np.int32)
            idx[:m] = np.arange(m, dtype=np.int32)
            vals = np.empty((p, self.graph.feat_dim), np.float32)
            if m:
                vals[:m] = self.graph.features[miss_ids]
            enc_dev, aux = _fused_pack(aux, idx, vals, enc)
            self._aux_bufs[cap] = aux
            self.gather_dispatches += 1
            self.gather_rows += n
            c.account_fetch(hit, miss_ids)
            return enc_dev, aux, self._dev_table

    def fill_rows(self, ids: np.ndarray, rows: np.ndarray):
        with self._lock:
            super().fill_rows(ids, rows)

    def _on_store_update(self, ids: np.ndarray, rows: np.ndarray):
        with self._lock:
            super()._on_store_update(ids, rows)

    def resize(self, volume_mb: float, keep_residents: bool = True):
        with self._lock:
            super().resize(volume_mb, keep_residents=keep_residents)


def make_feature_plane(graph: Graph, cache: Optional[FeatureCache],
                       sampling_device: str = "cpu") -> FeaturePlane:
    """Backend factory for the batch-generation gather stage.

    ``cpu`` → ``HostFeaturePlane``; ``device`` → ``DeviceFeaturePlane``;
    ``auto`` probes ``jax.devices()`` and picks the device plane only when
    a real accelerator (TPU/GPU) is attached.
    """
    if sampling_device == "auto":
        import jax
        has_accel = any(d.platform in ("tpu", "gpu") for d in jax.devices())
        sampling_device = "device" if has_accel else "cpu"
    if sampling_device == "device":
        return DeviceFeaturePlane(graph, cache)
    if sampling_device in ("cpu", "host"):
        return HostFeaturePlane(graph, cache)
    raise ValueError(f"unknown sampling_device: {sampling_device!r} "
                     f"(expected cpu | device | auto)")
