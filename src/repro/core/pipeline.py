"""Multi-level parallelism scheduling (paper §III-B, Fig. 4).

Three executors over the Algo.-1 stages (sample → batch-generate → train):

  * ``seq``    — one stage at a time; minimum memory, minimum throughput.
  * ``mode1``  — n workers each run (sample + batch-generate) and feed a
    bounded queue; the consumer trains.  Max throughput, n× working-set
    duplication (Eq. 3).
  * ``mode2``  — n workers run sampling only; batch generation (the
    contention-heavy stage: cache read/write) + training stay serialized on
    the consumer (Eq. 4/5).

On the host-TPU adaptation workers are threads (numpy sampling releases the
GIL in the hot gather ops) and the bounded queue doubles as the
double-buffer: while the device runs step k, workers prepare k+1.  Worker
failures are tolerated: a heartbeat thread re-issues the failed seed batch
(fault_tolerance.py provides the same machinery for the LM trainer).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.cache import FeatureCache
from repro.core.sampling import NeighborSampler, MiniBatch, seed_loader
from repro.graph.batch import generate_batch, batch_device_arrays, batch_bytes


@dataclass
class PipelineStats:
    steps: int = 0
    t_sample: float = 0.0
    t_batch: float = 0.0
    t_train: float = 0.0
    t_wall: float = 0.0
    peak_batch_bytes: int = 0
    queue_peak: int = 0
    losses: List[float] = field(default_factory=list)
    accs: List[float] = field(default_factory=list)
    reissued: int = 0

    def stage_times(self):
        from repro.core.perf_model import StageTimes
        n = max(self.steps, 1)
        return StageTimes(self.t_sample / n, self.t_batch / n, self.t_train / n)

    def throughput_steps_per_s(self) -> float:
        return self.steps / self.t_wall if self.t_wall else 0.0


class _SampleWorker(threading.Thread):
    """Pulls seed batches from an index queue, produces (mini)batches."""

    def __init__(self, wid, sampler, cache, graph, in_q, out_q, stats_lock,
                 stats, do_batchgen, heartbeat, fail_after=None):
        super().__init__(daemon=True)
        self.wid = wid
        self.sampler, self.cache, self.graph = sampler, cache, graph
        self.in_q, self.out_q = in_q, out_q
        self.stats_lock, self.stats = stats_lock, stats
        self.do_batchgen = do_batchgen
        self.heartbeat = heartbeat
        self.fail_after = fail_after        # fault-injection for tests
        self._count = 0

    def run(self):
        while True:
            item = self.in_q.get()
            if item is None:
                self.in_q.task_done()
                break
            idx, seeds = item
            try:
                if self.fail_after is not None and self._count >= self.fail_after:
                    raise RuntimeError(f"injected failure in worker {self.wid}")
                t0 = time.perf_counter()
                mb = self.sampler.sample(seeds)
                t1 = time.perf_counter()
                if self.do_batchgen:
                    mb = generate_batch(mb, self.cache, self.graph)
                t2 = time.perf_counter()
                with self.stats_lock:
                    self.stats.t_sample += t1 - t0
                    self.stats.t_batch += t2 - t1
                self.heartbeat[self.wid] = time.time()
                self._count += 1
                self.out_q.put((idx, seeds, mb))
            except Exception:  # noqa: BLE001 — re-queue the work item
                self.heartbeat[self.wid] = -1.0   # mark dead
                self.out_q.put((idx, seeds, None))
            finally:
                self.in_q.task_done()


class Pipeline:
    """Executes one epoch (or ``max_steps``) under a given mode."""

    def __init__(self, graph, cfg, train_fn: Callable[[MiniBatch], tuple],
                 cache: Optional[FeatureCache] = None,
                 weight_fn=None, seed: int = 0):
        self.graph, self.cfg = graph, cfg
        self.train_fn = train_fn
        self.cache = cache
        self.weight_fn = weight_fn
        self.seed = seed

    def _make_sampler(self, s=0):
        return NeighborSampler(self.graph, self.cfg.fanout,
                               weight_fn=self.weight_fn, seed=self.seed + s)

    # ------------------------------------------------------------------
    def run(self, mode: Optional[str] = None, max_steps: Optional[int] = None,
            fail_worker: Optional[int] = None) -> PipelineStats:
        mode = mode or self.cfg.parallel_mode
        if mode == "seq":
            return self._run_seq(max_steps)
        return self._run_parallel(mode, max_steps, fail_worker)

    # ------------------------------------------------------------------
    def _run_seq(self, max_steps) -> PipelineStats:
        stats = PipelineStats()
        sampler = self._make_sampler()
        t_start = time.perf_counter()
        for seeds in seed_loader(self.graph, self.cfg.batch_size, self.seed):
            if max_steps is not None and stats.steps >= max_steps:
                break
            t0 = time.perf_counter()
            mb = sampler.sample(seeds)
            t1 = time.perf_counter()
            mb = generate_batch(mb, self.cache, self.graph)
            t2 = time.perf_counter()
            loss, acc = self.train_fn(mb)
            t3 = time.perf_counter()
            stats.t_sample += t1 - t0
            stats.t_batch += t2 - t1
            stats.t_train += t3 - t2
            stats.steps += 1
            stats.losses.append(float(loss))
            stats.accs.append(float(acc))
            stats.peak_batch_bytes = max(stats.peak_batch_bytes, batch_bytes(mb))
        stats.t_wall = time.perf_counter() - t_start
        return stats

    # ------------------------------------------------------------------
    def _run_parallel(self, mode: str, max_steps, fail_worker) -> PipelineStats:
        n = max(self.cfg.workers, 1)
        stats = PipelineStats()
        lock = threading.Lock()
        in_q: queue.Queue = queue.Queue()
        out_q: queue.Queue = queue.Queue(maxsize=2 * n)   # bounded double-buffer
        heartbeat: Dict[int, float] = {}
        do_batchgen = (mode == "mode1")

        workers = []
        for w in range(n):
            fa = None
            if fail_worker is not None and w == fail_worker:
                fa = 2                                     # fail after 2 batches
            wk = _SampleWorker(w, self._make_sampler(w), self.cache, self.graph,
                               in_q, out_q, lock, stats, do_batchgen,
                               heartbeat, fail_after=fa)
            wk.start()
            workers.append(wk)

        seed_batches = list(seed_loader(self.graph, self.cfg.batch_size,
                                        self.seed))
        if max_steps is not None:
            seed_batches = seed_batches[:max_steps]
        for i, seeds in enumerate(seed_batches):
            in_q.put((i, seeds))

        spare = self._make_sampler(997)                    # straggler/failure spare
        t_start = time.perf_counter()
        done = 0
        while done < len(seed_batches):
            idx, seeds, mb = out_q.get()
            stats.queue_peak = max(stats.queue_peak, out_q.qsize())
            if mb is None:                                 # failed worker → re-issue
                stats.reissued += 1
                t0 = time.perf_counter()
                mb = spare.sample(seeds)
                mb = generate_batch(mb, self.cache, self.graph)
                with lock:
                    stats.t_sample += time.perf_counter() - t0
            elif not do_batchgen:                          # mode2: serialize batchgen
                t0 = time.perf_counter()
                mb = generate_batch(mb, self.cache, self.graph)
                with lock:
                    stats.t_batch += time.perf_counter() - t0
            t0 = time.perf_counter()
            loss, acc = self.train_fn(mb)
            t1 = time.perf_counter()
            with lock:
                stats.t_train += t1 - t0
                stats.steps += 1
                stats.losses.append(float(loss))
                stats.accs.append(float(acc))
                stats.peak_batch_bytes = max(stats.peak_batch_bytes,
                                             batch_bytes(mb))
            done += 1
        stats.t_wall = time.perf_counter() - t_start
        for _ in workers:
            in_q.put(None)
        for wk in workers:
            wk.join(timeout=5)
        return stats
