"""Multi-level parallelism scheduling (paper §III-B, Fig. 4).

Three executors over the Algo.-1 stages (sample → batch-generate → train):

  * ``seq``    — one stage at a time; minimum memory, minimum throughput.
  * ``mode1``  — n workers each run (sample + batch-generate) and feed a
    bounded queue; the consumer trains.  Max throughput, n× working-set
    duplication (Eq. 3).
  * ``mode2``  — n workers run sampling only; batch generation (the
    contention-heavy stage: cache read/write) + training stay serialized on
    the consumer (Eq. 4/5).

On the host-TPU adaptation workers are threads (numpy sampling releases the
GIL in the hot gather ops) and the bounded queue doubles as the
double-buffer: while the device runs step k, workers prepare k+1.  Worker
failures are tolerated: a failed seed batch is re-issued on a spare sampler
(fault_tolerance.py provides the same machinery for the LM trainer).

The executor is RECONFIGURABLE at an episode boundary (the autotune
controller's drain → reconfigure → resume contract):

  * ``submit()`` / ``step()`` — producer/consumer decoupled; in-flight work
    is tracked so nothing is ever dropped.
  * ``drain()`` — consume (train on) every submitted-but-unconsumed batch.
  * ``reconfigure()`` — drain, then atomically swap any of (mode, workers,
    cache, weight_fn, batch_size); the worker pool is rebuilt lazily with
    the new sampler bias/cache on the next submit.
  * ``run()`` — the classic one-epoch entry point, now submit+drain on the
    persistent pool; ``shutdown()`` releases the worker threads.
"""
from __future__ import annotations

import inspect
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.core.cache import FeatureCache
from repro.core.feature_plane import FeaturePlane, make_feature_plane
from repro.core.sampling import NeighborSampler, MiniBatch, seed_loader
from repro.graph.batch import generate_batch, batch_bytes

_UNSET = object()


@dataclass
class PipelineStats:
    steps: int = 0
    t_sample: float = 0.0
    t_batch: float = 0.0
    t_train: float = 0.0
    t_wall: float = 0.0
    peak_batch_bytes: int = 0
    queue_peak: int = 0
    losses: List[float] = field(default_factory=list)
    accs: List[float] = field(default_factory=list)
    reissued: int = 0

    def stage_times(self):
        from repro.core.perf_model import StageTimes
        n = max(self.steps, 1)
        return StageTimes(self.t_sample / n, self.t_batch / n, self.t_train / n)

    def throughput_steps_per_s(self) -> float:
        return self.steps / self.t_wall if self.t_wall else 0.0


class _SampleWorker(threading.Thread):
    """Pulls seed batches from an index queue, produces (mini)batches.

    Stats are written into ``pipeline.stats`` (re-read on every item, so an
    episode-boundary ``begin_stats()`` swap takes effect immediately)."""

    def __init__(self, wid, sampler, pipeline, in_q, out_q, do_batchgen,
                 heartbeat, fail_after=None):
        super().__init__(daemon=True)
        self.wid = wid
        self.sampler = sampler
        self.pipe = pipeline
        self.in_q, self.out_q = in_q, out_q
        self.do_batchgen = do_batchgen
        self.heartbeat = heartbeat
        self.fail_after = fail_after        # fault-injection for tests
        self._count = 0

    def run(self):
        while True:
            item = self.in_q.get()
            if item is None:
                self.in_q.task_done()
                break
            idx, seeds = item
            try:
                if self.fail_after is not None and self._count >= self.fail_after:
                    raise RuntimeError(f"injected failure in worker {self.wid}")
                t0 = time.perf_counter()
                mb = self.sampler.sample(seeds)
                t1 = time.perf_counter()
                if self.do_batchgen:
                    mb = generate_batch(mb, self.pipe.plane, self.pipe.graph,
                                        fused=self.pipe.fused)
                t2 = time.perf_counter()
                with self.pipe._lock:
                    self.pipe.stats.t_sample += t1 - t0
                    self.pipe.stats.t_batch += t2 - t1
                self.heartbeat[self.wid] = time.time()
                self._count += 1
                self.out_q.put((idx, seeds, mb))
            except Exception:  # noqa: BLE001 — re-queue the work item
                self.heartbeat[self.wid] = -1.0   # mark dead
                self.out_q.put((idx, seeds, None))
            finally:
                self.in_q.task_done()


class Pipeline:
    """Persistent, reconfigurable executor over the Algo.-1 stages."""

    def __init__(self, graph, cfg, train_fn: Callable[[MiniBatch], tuple],
                 cache: Optional[FeatureCache] = None,
                 weight_fn=None, seed: int = 0,
                 plane: Optional[FeaturePlane] = None):
        self.graph, self.cfg = graph, cfg
        self.train_fn = train_fn
        # the feature plane is the ONLY seam batch generation fetches
        # through; `cache` remains the constructor currency (trainers own
        # the cache object) and the plane wraps it per sampling_device
        self.plane = plane if plane is not None else make_feature_plane(
            graph, cache, getattr(cfg, "sampling_device", "cpu"))
        self.sampling_device = self.plane.backend
        self.weight_fn = weight_fn
        self.seed = seed
        self.mode = cfg.parallel_mode
        # all-hop fused batch generation (any model family): feature work
        # is DEFERRED to the train step, which resolves the input hop
        # through FeaturePlane.fused_inputs (encoded slots + sideband)
        self.fused = getattr(cfg, "fused_gather_agg", False)
        # fused train fns take (mb, plane) so step-time encoding reads
        # the LIVE plane (reconfigure may swap it); legacy single-arg
        # train fns keep working unchanged
        self._train_wants_plane = (
            len(inspect.signature(train_fn).parameters) >= 2)
        self.workers_n = max(cfg.workers, 1)
        self.batch_size = cfg.batch_size
        self.stats = PipelineStats()
        self._lock = threading.Lock()
        self.heartbeat: Dict[int, float] = {}
        # pool state
        self._workers: List[_SampleWorker] = []
        self._in_q: Optional[queue.Queue] = None
        self._out_q: Optional[queue.Queue] = None
        self._pool_key = None                  # (do_batchgen, n) of live pool
        self._submit_idx = 0
        self._inflight = 0                     # parallel: submitted, unconsumed
        self._pending: List[np.ndarray] = []   # seq: submitted, unconsumed
        self._spare: Optional[NeighborSampler] = None
        self._seq_sampler: Optional[NeighborSampler] = None
        self._pool_transient = False
        self._epoch = 0                        # advances the seed shuffle

    def _make_sampler(self, s=0):
        return NeighborSampler(self.graph, self.cfg.fanout,
                               weight_fn=self.weight_fn, seed=self.seed + s)

    @property
    def cache(self) -> Optional[FeatureCache]:
        """The cache behind the plane (hit/miss accounting lives there)."""
        return self.plane.cache

    # -- stats windows -------------------------------------------------------
    def begin_stats(self) -> PipelineStats:
        """Open a fresh measurement window (e.g. one autotune episode)."""
        with self._lock:
            self.stats = PipelineStats()
            return self.stats

    # -- worker pool ---------------------------------------------------------
    def _start_pool(self, do_batchgen: bool, fail_worker=None):
        n = self.workers_n
        self._in_q = queue.Queue()
        self._out_q = queue.Queue(maxsize=2 * n)   # bounded double-buffer
        self._workers = []
        for w in range(n):
            fa = 2 if (fail_worker is not None and w == fail_worker) else None
            wk = _SampleWorker(w, self._make_sampler(w), self,
                               self._in_q, self._out_q, do_batchgen,
                               self.heartbeat, fail_after=fa)
            wk.start()
            self._workers.append(wk)
        self._pool_key = (do_batchgen, n)
        self._pool_transient = fail_worker is not None

    def _stop_pool(self):
        if self._workers:
            for _ in self._workers:
                self._in_q.put(None)
            for wk in self._workers:
                wk.join(timeout=5)
        self._workers = []
        self._in_q = self._out_q = None
        self._pool_key = None
        self._pool_transient = False

    def _ensure_pool(self, mode: str, fail_worker=None):
        do_batchgen = (mode == "mode1")
        want = (do_batchgen, self.workers_n)
        if (fail_worker is not None or self._pool_key != want
                or self._pool_transient):
            if self._inflight:
                self.drain()       # never discard queued work on a rebuild
            self._stop_pool()
            self._start_pool(do_batchgen, fail_worker)

    # -- produce / consume ---------------------------------------------------
    def submit(self, seed_batches, fail_worker=None):
        """Queue seed batches for execution under the CURRENT mode."""
        if self.mode == "seq":
            self._pending.extend(seed_batches)
            return
        self._ensure_pool(self.mode, fail_worker)
        for seeds in seed_batches:
            self._in_q.put((self._submit_idx, seeds))
            self._submit_idx += 1
            self._inflight += 1

    @property
    def inflight(self) -> int:
        return len(self._pending) + self._inflight

    def step(self) -> bool:
        """Consume (train on) exactly one submitted batch.  Returns False if
        nothing is in flight."""
        if self.mode == "seq" or self._pending:
            if not self._pending:
                return False
            seeds = self._pending.pop(0)
            if self._seq_sampler is None:
                self._seq_sampler = self._make_sampler()
            t0 = time.perf_counter()
            mb = self._seq_sampler.sample(seeds)
            t1 = time.perf_counter()
            mb = generate_batch(mb, self.plane, self.graph,
                                fused=self.fused)
            t2 = time.perf_counter()
            loss, acc = self._train(mb)
            t3 = time.perf_counter()
            with self._lock:
                st = self.stats
                st.t_sample += t1 - t0
                st.t_batch += t2 - t1
                self._record_train(st, mb, loss, acc, t3 - t2)
            return True
        if self._inflight == 0:
            return False
        do_batchgen = self._pool_key[0] if self._pool_key else True
        idx, seeds, mb = self._out_q.get()
        self._inflight -= 1
        with self._lock:
            self.stats.queue_peak = max(self.stats.queue_peak,
                                        self._out_q.qsize())
        if mb is None:                                 # failed worker → re-issue
            if self._spare is None:
                self._spare = self._make_sampler(997)  # straggler/failure spare
            t0 = time.perf_counter()
            mb = self._spare.sample(seeds)
            mb = generate_batch(mb, self.plane, self.graph,
                                fused=self.fused)
            with self._lock:
                self.stats.reissued += 1
                self.stats.t_sample += time.perf_counter() - t0
        elif not do_batchgen:                          # mode2: serialize batchgen
            t0 = time.perf_counter()
            mb = generate_batch(mb, self.plane, self.graph,
                                fused=self.fused)
            with self._lock:
                self.stats.t_batch += time.perf_counter() - t0
        t0 = time.perf_counter()
        loss, acc = self._train(mb)
        t1 = time.perf_counter()
        with self._lock:
            self._record_train(self.stats, mb, loss, acc, t1 - t0)
        return True

    def _train(self, mb):
        if self._train_wants_plane:
            return self.train_fn(mb, self.plane)
        return self.train_fn(mb)

    def _record_train(self, st: PipelineStats, mb, loss, acc, dt: float):
        st.t_train += dt
        st.steps += 1
        st.losses.append(float(loss))
        st.accs.append(float(acc))
        st.peak_batch_bytes = max(st.peak_batch_bytes, batch_bytes(mb))

    def drain(self):
        """Consume every in-flight batch (nothing is dropped)."""
        while self.step():
            pass

    # -- reconfiguration -----------------------------------------------------
    def reconfigure(self, mode: Optional[str] = None,
                    workers: Optional[int] = None,
                    cache: Any = _UNSET, weight_fn: Any = _UNSET,
                    batch_size: Optional[int] = None,
                    sampling_device: Optional[str] = None):
        """Drain → swap knobs → (lazy) resume.

        Safe at any point: all in-flight batches are trained under the OLD
        configuration first, then the pool is torn down so the next submit
        rebuilds samplers with the new bias/cache.  ``sampling_device``
        swaps the feature-plane backend LIVE (cpu ↔ device) around the same
        cache object — hit/miss accounting survives the migration."""
        self.drain()
        self._stop_pool()
        self._spare = None
        self._seq_sampler = None
        if mode is not None:
            self.mode = mode
        if workers is not None:
            self.workers_n = max(int(workers), 1)
        if cache is not _UNSET or sampling_device is not None:
            if sampling_device is not None:
                self.sampling_device = sampling_device
            new_cache = self.plane.cache if cache is _UNSET else cache
            # rebuild only on a real change — a same-cache re-sync (every
            # apply_live_config passes cache=) must keep the existing plane
            # so a device mirror is not pointlessly re-uploaded; in-place
            # cache mutation is covered by FeatureCache.version
            if (new_cache is not self.plane.cache
                    or self.sampling_device != self.plane.backend):
                old_plane = self.plane
                self.plane = make_feature_plane(self.graph, new_cache,
                                                self.sampling_device)
                self.sampling_device = self.plane.backend
                # a FeatureStore subscription follows the LIVE plane: the
                # dead plane detaches (no stale routing, nothing pinned)
                # and the successor observes all further streamed updates
                if old_plane.store is not None:
                    store = old_plane.store
                    old_plane.detach_store()
                    self.plane.subscribe_to(store)
        if weight_fn is not _UNSET:
            self.weight_fn = weight_fn
        if batch_size is not None:
            self.batch_size = int(batch_size)

    def shutdown(self):
        """Discard pending work and stop the workers.

        Unlike ``reconfigure`` this does NOT train the backlog: shutdown is
        called from ``finally`` blocks during exception unwind, where
        re-entering ``train_fn`` would mask the original error (or continue
        training after a fault).  Callers on the green path have already
        drained — ``run()`` consumes everything it submits."""
        self._pending.clear()
        self._inflight = 0
        # unblock any worker parked on a full out_q, and pull undispatched
        # items so the stop sentinels are consumed promptly
        for q_ in (self._out_q, self._in_q):
            if q_ is None:
                continue
            while True:
                try:
                    q_.get_nowait()
                except queue.Empty:
                    break
        self._stop_pool()

    # -- classic one-epoch entry point --------------------------------------
    def run(self, mode: Optional[str] = None, max_steps: Optional[int] = None,
            fail_worker: Optional[int] = None) -> PipelineStats:
        if mode is not None and mode != self.mode:
            self.reconfigure(mode=mode)
        stats = self.begin_stats()
        # each run window gets a fresh shuffle — autotune episodes must not
        # re-measure the identical batch prefix (a FIFO cache would look
        # steady-state-optimal on repeats)
        seed_batches = list(seed_loader(self.graph, self.batch_size,
                                        self.seed + self._epoch))
        self._epoch += 1
        if max_steps is not None:
            seed_batches = seed_batches[:max_steps]
        t_start = time.perf_counter()
        if self.mode == "seq":
            self.submit(seed_batches)
            self.drain()
        else:
            self.submit(seed_batches, fail_worker=fail_worker)
            self.drain()
            if fail_worker is not None:
                self._stop_pool()      # injected-failure pool is poisoned
        stats.t_wall = time.perf_counter() - t_start
        return stats
